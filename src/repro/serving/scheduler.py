"""Multi-stream batched scheduler for always-on KWS serving.

Slot-based continuous-batching-light (the KWS analogue of
``repro.launch.serve``'s decoder slots): a fixed number of stream slots,
each holding one live audio stream's incremental ``StreamState``.  Every
``step()`` batches ALL hop-ready slots' fresh frames into one
``stream_step`` call — i.e. exactly one fused-kernel launch per IMC layer
for the whole fleet of streams, the M-tiling of the fused kernel amortizing
the weight-stationary packs across streams.  Slots that are not ready this
step ride along masked (their state is restored verbatim; their logits are
ignored), so the launch count is independent of readiness.

Host side, each stream owns a ring buffer of pending samples
(``submit()`` appends arbitrary-sized chunks); a stream is admitted to a
free slot immediately, waits buffered in an admission queue otherwise, and
is evicted when its producer calls ``finish()`` and its buffer drains (or
explicitly via ``evict()``).  Admission runs the stream's first full window
(``stream_init``) and scatters the result into the slot.

Per-hop logits flow into the shared decision head
(repro.serving.decision): smoothing + hysteresis + refractory, batched and
mask-aware.  ``stats()`` reports per-stream and aggregate decisions/sec,
hop latency, and the streaming-vs-recompute MAC counts per decision.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import kws
from repro.serving import decision as dec
from repro.serving import stream as sv


@dataclasses.dataclass
class _Stream:
    stream_id: str
    uid: int
    buf: np.ndarray                       # pending samples (host ring tail)
    slot: Optional[int] = None
    initialized: bool = False
    finished: bool = False                # producer called finish()
    hops: int = 0                         # decisions made (incl. window 0)
    triggers: List[dict] = dataclasses.field(default_factory=list)
    wall_s: float = 0.0                   # server time attributed to it


def _select_state(mask: jax.Array, new, old):
    def sel(n, o):
        m = mask.reshape(mask.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree_util.tree_map(sel, new, old)


def _scatter_slot(state, one, slot):
    return jax.tree_util.tree_map(lambda full, o: full.at[slot].set(o[0]),
                                  state, one)


class StreamServer:
    """Admit / batch / decide / evict over a fixed number of stream slots."""

    def __init__(self, hw, cfg: kws.KWSConfig, *, hop: int, slots: int = 4,
                 chip_offsets: Optional[Dict[str, jax.Array]] = None,
                 sa_noise_std: float = 0.0, use_kernel: bool = True,
                 streaming: bool = True,
                 decision: dec.DecisionConfig = dec.DecisionConfig(),
                 seed: int = 0):
        self.cfg = cfg
        self.slots = slots
        self.streaming = streaming
        self.engine = sv.StreamEngine(hw, cfg, hop,
                                      chip_offsets=chip_offsets,
                                      sa_noise_std=sa_noise_std,
                                      use_kernel=use_kernel,
                                      streaming=streaming)
        self.geom = self.engine.geom
        self.dcfg = decision
        self._state = self.engine.zeros_state(slots)
        self._dstate = dec.decision_init(slots, cfg.num_classes, decision)
        self._slots: List[Optional[_Stream]] = [None] * slots
        self._queue: collections.deque[_Stream] = collections.deque()
        self._streams: Dict[str, _Stream] = {}
        self._base_key = jax.random.PRNGKey(seed)
        self._uid = 0
        self._steps = 0
        self._hop_wall_s = 0.0
        self._decisions = 0

        def hop_masked(state, audio, mask):
            logits, new_state = self.engine._step(state, audio)
            return logits, _select_state(mask, new_state, state)

        self._hop = jax.jit(hop_masked)
        self._decide = jax.jit(
            lambda dstate, logits, active: dec.decision_step(
                self.dcfg, dstate, logits, active))
        self._scatter = jax.jit(_scatter_slot)

    # -- stream lifecycle ---------------------------------------------------

    def submit(self, stream_id: str, chunk: np.ndarray) -> str:
        """Append audio to a stream (created on first submit).  Returns the
        stream's placement: 'slot' (live) or 'queued' (awaiting a slot)."""
        rec = self._streams.get(stream_id)
        if rec is None:
            rec = _Stream(stream_id=stream_id, uid=self._uid,
                          buf=np.zeros((0,), np.float32))
            self._uid += 1
            self._streams[stream_id] = rec
            self._queue.append(rec)
            self._try_admit()
        if rec.finished:
            raise ValueError(f"stream {stream_id} already finished")
        rec.buf = np.concatenate([rec.buf, np.asarray(chunk, np.float32)])
        return "slot" if rec.slot is not None else "queued"

    def finish(self, stream_id: str) -> None:
        """Producer signals end-of-stream: the slot is freed once the
        buffered audio drains below one hop."""
        self._streams[stream_id].finished = True

    def evict(self, stream_id: str) -> None:
        """Drop a stream immediately, freeing its slot."""
        rec = self._streams[stream_id]
        rec.finished = True
        rec.buf = rec.buf[:0]
        if rec.slot is not None:
            self._free_slot(rec)
        elif rec in self._queue:
            self._queue.remove(rec)

    def _free_slot(self, rec: _Stream) -> None:
        self._slots[rec.slot] = None
        rec.slot = None
        self._try_admit()

    def _try_admit(self) -> None:
        for s in range(self.slots):
            if self._slots[s] is None and self._queue:
                rec = self._queue.popleft()
                rec.slot = s
                rec.initialized = False
                self._slots[s] = rec

    # -- the batched hop ----------------------------------------------------

    def _admit_ready(self):
        """Initialize any slotted stream whose buffer holds a full window.
        Returns (init_mask, init_logits) rows for this step's decisions."""
        window = self.geom.window
        init_mask = np.zeros((self.slots,), bool)
        init_logits = np.zeros((self.slots, self.cfg.num_classes),
                               np.float32)
        for s, rec in enumerate(self._slots):
            if rec is None or rec.initialized or len(rec.buf) < window:
                continue
            first = jnp.asarray(rec.buf[None, :window])
            rec.buf = rec.buf[window:]   # the state carries the overlap;
                                         # later hops feed fresh samples only
            key = jax.random.fold_in(self._base_key, rec.uid)[None]
            t0 = time.perf_counter()
            logits, one = self.engine.init(first, key)
            self._state = self._scatter(self._state, one, s)
            self._dstate = dec.reset_slot(self._dstate, s)
            dt = time.perf_counter() - t0
            rec.wall_s += dt
            # the window-0 decision counts toward throughput, so its time
            # must count too (decisions_per_sec = decisions / hop_wall_s)
            self._hop_wall_s += dt
            rec.initialized = True
            rec.hops = 1
            init_mask[s] = True
            init_logits[s] = np.asarray(logits[0])
        return init_mask, init_logits

    def step(self) -> List[dict]:
        """One scheduler tick: admissions, then ONE batched hop over every
        hop-ready slot, then the batched decision update.  Returns this
        tick's decision events (one per deciding stream)."""
        hop = self.geom.hop
        init_mask, init_logits = self._admit_ready()

        hop_mask = np.zeros((self.slots,), bool)
        audio = np.zeros((self.slots, hop), np.float32)
        for s, rec in enumerate(self._slots):
            if (rec is not None and rec.initialized and not init_mask[s]
                    and len(rec.buf) >= hop):
                hop_mask[s] = True
                audio[s] = rec.buf[:hop]
                rec.buf = rec.buf[hop:]

        logits = init_logits
        if hop_mask.any():
            t0 = time.perf_counter()
            mask_j = jnp.asarray(hop_mask)
            hop_logits, self._state = self._hop(self._state,
                                               jnp.asarray(audio), mask_j)
            hop_logits.block_until_ready()
            dt = time.perf_counter() - t0
            self._hop_wall_s += dt
            n_active = int(hop_mask.sum())
            for s, rec in enumerate(self._slots):
                if hop_mask[s]:
                    rec.hops += 1
                    rec.wall_s += dt / n_active
            logits = np.where(hop_mask[:, None], np.asarray(hop_logits),
                              init_logits)

        active = jnp.asarray(init_mask | hop_mask)
        events: List[dict] = []
        if bool(init_mask.any() or hop_mask.any()):
            self._dstate, out = self._decide(self._dstate,
                                             jnp.asarray(logits), active)
            self._decisions += int((init_mask | hop_mask).sum())
            trig = np.asarray(out.trigger)
            kwd = np.asarray(out.keyword)
            score = np.asarray(out.score)
            for s, rec in enumerate(self._slots):
                if rec is None or not (init_mask[s] or hop_mask[s]):
                    continue
                ev = {"stream": rec.stream_id, "hop": rec.hops - 1,
                      "keyword": int(kwd[s]), "score": float(score[s]),
                      "trigger": bool(trig[s])}
                events.append(ev)
                if ev["trigger"]:
                    rec.triggers.append(ev)

        # retire drained finished streams
        for rec in list(self._slots):
            if (rec is not None and rec.finished
                    and len(rec.buf) < (hop if rec.initialized
                                        else self.geom.window)):
                self._free_slot(rec)
        self._steps += 1
        return events

    def drain(self, max_steps: int = 10_000) -> List[dict]:
        """Step until no slot can make progress and the queue is empty."""
        events: List[dict] = []
        for _ in range(max_steps):
            before = (len(self._queue),
                      [None if r is None else len(r.buf)
                       for r in self._slots])
            events.extend(self.step())
            after = (len(self._queue),
                     [None if r is None else len(r.buf)
                      for r in self._slots])
            if after == before:
                break
        return events

    # -- accounting ---------------------------------------------------------

    def active_streams(self) -> List[str]:
        return [r.stream_id for r in self._slots if r is not None]

    def stats(self) -> dict:
        offline = kws.layer_stats(self.cfg)
        streaming = sv.streaming_layer_stats(self.cfg, self.geom)
        macs_off = sum(s["macs"] for s in offline)
        macs_str = sum(s["macs"] for s in streaming)
        per_stream = {
            rec.stream_id: {
                "hops": rec.hops,
                "triggers": len(rec.triggers),
                "wall_s": round(rec.wall_s, 4),
            }
            for rec in self._streams.values()
        }
        return {
            "mode": "streaming" if self.streaming else "recompute",
            "slots": self.slots,
            "steps": self._steps,
            "decisions": self._decisions,
            "hop_wall_s": round(self._hop_wall_s, 4),
            "decisions_per_sec": round(
                self._decisions / self._hop_wall_s, 2)
                if self._hop_wall_s > 0 else None,
            "macs_per_decision": {
                "offline": macs_off,
                "streaming": macs_str,
                "ratio": round(macs_str / macs_off, 4),
            },
            "per_stream": per_stream,
        }
