"""Always-on streaming KWS serving over the hardware-folded model.

The deployment shape of the paper's accelerator: a sliding decision window
advanced by a hop, with frame-incremental reuse of every IMC layer's
activation columns between hops (the per-decision work drops to roughly
hop/window of a full forward), a voice-activity gate in front of the
compute (silent hops advance state by a no-op fill and are charged
leakage-only), a smoothed/hysteresis decision head, and a slot-based
scheduler that batches many live streams into one fused-kernel launch per
layer with dynamic hop widening and admission control.

  stream.py     — hop geometry, per-stream ring state, init/step (+ the
                  multi-hop step and the per-stream bias-delta/head
                  riders), the per-absolute-column SA-noise field, the
                  gated (no-IMC) state advance, work accounting
  vad.py        — log-energy EMA + hysteresis voice-activity detector
  decision.py   — posterior smoothing + hysteresis + refractory triggers
  scheduler.py  — StreamServer: slots, admission queue + backpressure,
                  batched hops, VAD gating + wake replay, dynamic hop,
                  slot autoscaling, eviction, latency/throughput stats
  compiled.py   — whole-tick compiled fast path: K steady-state ticks
                  (VAD gate -> batched hop -> decision -> rider updates)
                  fused into one jitted lax.scan dispatch, bit-identical
                  to K interpreted ticks; structural events break out
                  to the Python tick
  shard.py      — ShardedStreamServer: N per-device slot pools (one
                  StreamServer per device) behind a deterministic
                  host-side placement router (repro.sharding); global
                  uid assignment keeps sharded serving bit-identical to
                  single-device per stream
  customize.py  — on-device customization as a serving workload:
                  enrollment sessions, scheduler-ticked bias compensation
                  + SGA fine-tuning, hot-swapped per-stream profiles
  health.py     — canary-based health monitoring and self-healing over
                  the fault models in repro.core.faults: periodic known
                  windows ride the batched tick, divergence localizes the
                  faulty layer/columns, background recompensation heals
                  drift/flip faults, unrecoverable columns are masked

Bit-exactness contracts: N hops of the streaming path equal ``hw_forward``
on each full window — noise and chip-offset configurations included;
``streaming=False`` falls back to exactly that recompute path; gated
serving with the VAD forced to "speech" is bit-identical to ungated
serving (silence never computes, so all-speech audio never gates); a
customization session driven through scheduler ticks equals the offline
customize loop on the same utterances (compensated biases + fine-tuned
head) — chip offsets AND SA-noise fields included, the offline oracle
evaluating the session's recorded per-absolute-column field
(``repro.core.sa_noise``); batched admission waves equal sequential B=1
admissions; and a profile persisted via
``repro.checkpoint.profiles.ProfileStore`` restores bit-identically
after a restart.
"""

from repro.core.faults import FaultConfig, FaultModel
from repro.core.sa_noise import SANoiseField
from repro.serving.compiled import CompiledTick, CompiledTickConfig
from repro.obs import (FlightRecorder, LaunchAuditError, LaunchAuditor,
                       MetricsRegistry, ObsConfig, TraceBuilder)
from repro.serving.customize import (CustomizationResult,
                                     CustomizationSession, CustomizeConfig)
from repro.serving.health import HealthConfig, HealthMonitor
from repro.serving.decision import (DecisionConfig, DecisionOut,
                                    DecisionState, decision_init,
                                    decision_step)
from repro.serving.scheduler import (AdmissionConfig, DynamicHopConfig,
                                     StreamServer)
from repro.serving.shard import ShardedStreamServer
from repro.serving.stream import (StreamEngine, StreamGeometry, StreamState,
                                  gated_step, gated_window_step,
                                  hop_alignment, hop_sa_noise_fields,
                                  make_stream_geometry, retention_fills,
                                  sa_noise_columns, silence_fills,
                                  stream_init, stream_multi_step,
                                  stream_step, streaming_layer_stats,
                                  window_sa_noise)
from repro.serving.vad import (VADConfig, VADState, frame_energy_db,
                               vad_init, vad_step)

__all__ = [
    "AdmissionConfig", "CompiledTick", "CompiledTickConfig",
    "CustomizationResult", "CustomizationSession",
    "CustomizeConfig", "DecisionConfig", "DecisionOut", "DecisionState",
    "DynamicHopConfig", "FaultConfig", "FaultModel", "FlightRecorder",
    "HealthConfig", "HealthMonitor", "LaunchAuditError", "LaunchAuditor",
    "MetricsRegistry", "ObsConfig", "SANoiseField", "ShardedStreamServer",
    "StreamServer",
    "StreamEngine", "StreamGeometry", "StreamState", "TraceBuilder",
    "VADConfig", "VADState", "decision_init",
    "decision_step", "frame_energy_db", "gated_step", "gated_window_step",
    "hop_alignment", "hop_sa_noise_fields", "make_stream_geometry",
    "retention_fills", "sa_noise_columns", "silence_fills", "stream_init",
    "stream_multi_step", "stream_step", "streaming_layer_stats", "vad_init",
    "vad_step", "window_sa_noise",
]
