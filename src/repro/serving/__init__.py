"""Always-on streaming KWS serving over the hardware-folded model.

The deployment shape of the paper's accelerator: a sliding decision window
advanced by a hop, with frame-incremental reuse of every IMC layer's
activation columns between hops (the per-decision work drops to roughly
hop/window of a full forward), a smoothed/hysteresis decision head, and a
slot-based scheduler that batches many live streams into one fused-kernel
launch per layer.

  stream.py     — hop geometry, per-stream ring state, init/step, the
                  per-absolute-column SA-noise field, work accounting
  decision.py   — posterior smoothing + hysteresis + refractory triggers
  scheduler.py  — StreamServer: slots, admission queue, batched hops,
                  eviction, latency/throughput stats

Bit-exactness contract: N hops of the streaming path equal ``hw_forward``
on each full window — noise and chip-offset configurations included — and
``streaming=False`` falls back to exactly that recompute path.
"""

from repro.serving.decision import (DecisionConfig, DecisionOut,
                                    DecisionState, decision_init,
                                    decision_step)
from repro.serving.scheduler import StreamServer
from repro.serving.stream import (StreamEngine, StreamGeometry, StreamState,
                                  hop_alignment, make_stream_geometry,
                                  sa_noise_columns, stream_init, stream_step,
                                  streaming_layer_stats, window_sa_noise)

__all__ = [
    "DecisionConfig", "DecisionOut", "DecisionState", "decision_init",
    "decision_step", "StreamServer", "StreamEngine", "StreamGeometry",
    "StreamState", "hop_alignment", "make_stream_geometry",
    "sa_noise_columns", "stream_init", "stream_step",
    "streaming_layer_stats", "window_sa_noise",
]
